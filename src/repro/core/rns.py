"""RNS/CRT pre- and post-processing (paper §IV-C/D/F, contribution 3).

Pre-processing (residual polynomial computation, Alg 1 / Alg 2):
  input coefficients arrive as base-B segments (B = 2^v, Alg 1 line 1):
      a_j = z_0 + z_1 B + ... + z_{t-1} B^{t-1}
  and each residue is  a_j mod q_i = sum_k z_k * (B^k mod q_i) mod q_i.
  Two datapaths are provided:
    * ``decompose``      — generic: precomputed (B^k mod q_i) constants and
      one multiply per segment (the Fig 11(a) baseline, minus its per-
      segment Barrett units).
    * ``decompose_sau``  — the paper's optimized path: multiplication by
      beta_i = B mod q_i done with Shift-Add Units (low-Hamming-weight
      special primes, Eq 5), factorized blocks of t' = 3 (Alg 2), one
      Barrett per block plus one generic v x v multiply for [beta^{t'rho}].
      int64 adaptation: SAU depth capped at 1 with a Barrett between SAU
      applications (the paper's own Approach-1 hybrid, Fig 14) because a
      depth-2 SAU word (v + 2(v1+1) bits) can exceed 63 bits.

Post-processing (inverse CRT, Eq 10 / HPS [33]):
      p = sum_i [p_i * q_i~]_{q_i} * q_i^  mod q
  with q_i^ = q / q_i held as base-2^w limbs; the final sum is < t*q and is
  reduced by at most (t-1) conditional subtractions — no Barrett over the
  full q is ever instantiated (the content of Fig 16(b)).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import bigint
from repro.core.modmath import barrett_constants, barrett_reduce  # noqa: F401
# ^ canonical implementations live in modmath (shared with the Pallas
#   kernels); re-exported here because the RNS datapaths and their tests
#   historically import them from this module.


# --------------------------------------------------------------------------
# Plan
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChannelDecompose:
    """Static pre-processing constants for ONE RNS channel (= one of the
    paper's specialized SAU circuits).

    Packaged on the plan so every in-kernel decompose stage — the
    standalone per-channel ``pallas_call`` in :mod:`repro.kernels.crt`
    and the fully fused e2e kernel in :mod:`repro.kernels.ntt` — bakes
    the same flat layout of python ints into its closure instead of
    re-deriving Barrett constants at every call site.
    """

    qi: int
    beta_terms: tuple[tuple[int, int], ...]  # signed-PoT terms of beta_i
    block_consts: tuple[int, ...]  # [beta_i^{t'*rho}]_{q_i} per Alg-2 block
    sau_barrett: tuple[int, int, int]  # (eps, s1, s2) for SAU/block words
    acc_barrett: tuple[int, int, int]  # (eps, s1, s2) for the accumulator


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: jit-static-safe
class RnsPlan:
    """All host-precomputed constants for one (n, v, t) RNS configuration."""

    n: int
    v: int
    t: int
    q: int  # composed modulus, prod(qs)
    qs: np.ndarray  # (t,) int64
    beta_terms: tuple[tuple[tuple[int, int], ...], ...]  # per prime
    # pre-processing
    seg_count: int  # number of base-2^v segments of an input coefficient
    beta_pows: np.ndarray  # (t, seg_count): B^k mod q_i
    t_prime: int  # Alg 2 block width (t')
    block_consts: np.ndarray  # (t, n_blocks): [beta_i^{t'*rho}]_{q_i}
    # post-processing
    w: int  # post-processing limb width
    L: int  # post-processing limb count
    qi_tilde: np.ndarray  # (t,): (q/q_i)^{-1} mod q_i
    qi_star_limbs: np.ndarray  # (t, L): q/q_i in base 2^w
    q_limbs: np.ndarray  # (L,)
    # per-channel in-kernel decompose constants; None when the int64
    # kernels cannot serve the config (v > 31, or a channel's SAU word
    # falls outside the 63-bit-safe Barrett window 2*(v1 + 4) <= 63) —
    # the jnp datapaths still work, the kernel entry points raise
    dec: tuple[ChannelDecompose, ...] | None = None

    @property
    def jnp_safe(self) -> bool:
        """int64 datapaths require q_i < 2^31; v=45 is served by the
        Python-bigint oracle in polymul.py."""
        return self.v <= 31

    # -- device-resident constants, uploaded once at construction time.
    # Eager on purpose: a lazy first touch could happen inside a jit
    # trace, where jnp.asarray yields a tracer that must not be cached.
    def __post_init__(self):
        object.__setattr__(self, "qs_d", jnp.asarray(self.qs))
        object.__setattr__(self, "beta_pows_d", jnp.asarray(self.beta_pows))
        object.__setattr__(self, "qi_tilde_d", jnp.asarray(self.qi_tilde))
        object.__setattr__(self, "qi_star_limbs_d", jnp.asarray(self.qi_star_limbs))
        object.__setattr__(self, "q_limbs_d", jnp.asarray(self.q_limbs))


def make_plan(qs: list[int], n: int, v: int, beta_terms, t_prime: int = 3) -> RnsPlan:
    t = len(qs)
    q = 1
    for qi in qs:
        q *= int(qi)
    seg_count = -(-q.bit_length() // v)
    beta_pows = np.array(
        [[pow(1 << v, k, int(qi)) for k in range(seg_count)] for qi in qs],
        dtype=np.int64,
    )
    n_blocks = -(-seg_count // t_prime)
    block_consts = np.array(
        [[pow(1 << v, t_prime * r, int(qi)) for r in range(n_blocks)] for qi in qs],
        dtype=np.int64,
    )
    w = 28
    # final accumulator < t * q: size limbs for that
    L = -(-(q.bit_length() + t.bit_length()) // w)
    qi_star = [q // int(qi) for qi in qs]
    qi_tilde = np.array(
        [pow(s % int(qi), int(qi) - 2, int(qi)) for s, qi in zip(qi_star, qs)],
        dtype=np.int64,
    )
    qi_star_limbs = bigint.ints_to_limbs(qi_star, w, L)
    q_limbs = bigint.int_to_limbs(q, w, L)
    dec = None
    # Same windows the constants below assert: SAU words need
    # 2*(v1 + 4) <= 63 per channel, accumulator words 2*4 <= 63.  Gating
    # here (instead of letting barrett_constants assert) keeps plan
    # construction working for every config the jnp datapaths serve —
    # only the in-kernel decompose circuits become unavailable.
    if v <= 31 and all(2 * (terms[0][0] + 4) <= 63 for terms in beta_terms):
        dec = tuple(
            ChannelDecompose(
                qi=int(qi),
                beta_terms=tuple(terms),
                block_consts=tuple(int(c) for c in block_consts[i]),
                # SAU output + block-sum headroom: c = v + v1 + 3 bits
                sau_barrett=barrett_constants(int(qi), v + terms[0][0] + 3, v),
                # accumulator of <= n_blocks reduced terms: < 2^{v+3}
                acc_barrett=barrett_constants(int(qi), v + 3, v),
            )
            for i, (qi, terms) in enumerate(zip(qs, beta_terms))
        )
    return RnsPlan(
        n=n,
        v=v,
        t=t,
        q=q,
        qs=np.array(qs, dtype=np.int64),
        beta_terms=tuple(beta_terms),
        seg_count=seg_count,
        beta_pows=beta_pows,
        t_prime=t_prime,
        block_consts=block_consts,
        w=w,
        L=L,
        qi_tilde=qi_tilde,
        qi_star_limbs=qi_star_limbs,
        q_limbs=q_limbs,
        dec=dec,
    )


# --------------------------------------------------------------------------
# Pre-processing
# --------------------------------------------------------------------------


def decompose(z: jnp.ndarray, plan: RnsPlan) -> jnp.ndarray:
    """Generic residue computation.  z: (..., S) base-2^v segments (each
    < 2^v) -> residues (t, ...)."""
    assert plan.jnp_safe
    qs = plan.qs_d  # (t,)
    bp = plan.beta_pows_d  # (t, S)
    terms = (z[..., None, :] * bp) % qs[:, None]  # (..., t, S)
    r = terms.sum(axis=-1) % qs  # (..., t)
    return jnp.moveaxis(r, -1, 0)


def _sau_mul_beta(z: jnp.ndarray, terms) -> jnp.ndarray:
    """z * beta via shifts/adds; beta = sum(sign * 2^e) - 1 (Eq 5, Fig 12).
    Input z < 2^v  ->  output < 2^{v + v1 + 1} (<= 52 bits for v<=30)."""
    acc = -z
    for e, s in terms:
        acc = acc + s * (z << e)
    return acc


def decompose_sau(z: jnp.ndarray, plan: RnsPlan) -> jnp.ndarray:
    """Paper-faithful optimized pre-processing (Alg 2 with SAUs).

    Per channel i, per block rho of t' segments:
        block = z_{rho t'} + SAU(z_{rho t' + 1}) + SAU(Barrett(SAU(z_{rho t'+2})))
        sum_rho = Barrett(block) * block_consts[i, rho]        (v x v multiply)
        a_i = Barrett(sum_rho accumulated)
    SAU depth capped at 1 (Approach-1 hybrid) for 63-bit safety.
    """
    S, tp = plan.seg_count, plan.t_prime
    n_blocks = -(-S // tp)
    pad = n_blocks * tp - S
    if pad:
        z = jnp.concatenate([z, jnp.zeros(z.shape[:-1] + (pad,), z.dtype)], axis=-1)
    outs = []
    for i in range(plan.t):
        qi = int(plan.qs[i])
        terms = plan.beta_terms[i]
        v1 = terms[0][0]
        c_sau = plan.v + v1 + 1 + 2  # SAU output + block-sum headroom
        eps, s1, s2 = barrett_constants(qi, c_sau, plan.v)
        # Accumulator of <= n_blocks already-reduced terms: < 2^{v+3}
        epsa, sa1, sa2 = barrett_constants(qi, plan.v + 3, plan.v)
        acc = jnp.zeros(z.shape[:-1], dtype=z.dtype)
        for rho in range(n_blocks):
            z0 = z[..., rho * tp + 0]
            blk = z0
            if tp > 1:
                blk = blk + _sau_mul_beta(z[..., rho * tp + 1], terms)
            for k in range(2, tp):
                # z * beta^k with Barrett between SAU applications (depth 1)
                x = _sau_mul_beta(z[..., rho * tp + k], terms)
                x = barrett_reduce(x, qi, eps, s1, s2)
                for _ in range(k - 1):
                    x = _sau_mul_beta(x, terms)
                    x = barrett_reduce(x, qi, eps, s1, s2)
                blk = blk + x
            blk = barrett_reduce(blk, qi, eps, s1, s2)
            if rho == 0:
                acc = acc + blk
            else:
                # The one generic v x v multiply per block (Eq 8).  The
                # paper reduces its 2v-bit product with the wide (mu-bit)
                # Barrett unit; a 63-bit-safe Barrett for c = 2v does not
                # exist for v = 30, so the int64 model uses rem here
                # (hardware cost accounting lives in benchmarks).
                prod = blk * int(plan.block_consts[i, rho])
                acc = acc + (prod % qi)
        acc = barrett_reduce(acc, qi, epsa, sa1, sa2)
        outs.append(acc)
    return jnp.stack(outs, axis=0)


# --------------------------------------------------------------------------
# Post-processing
# --------------------------------------------------------------------------


def compose(residues: jnp.ndarray, plan: RnsPlan) -> jnp.ndarray:
    """Inverse CRT per Eq 10: residues (t, ...) -> base-2^w limbs (..., L).

    No full-width Barrett over q: the t-term sum is < t*q and is finished
    with (t-1) conditional subtractions (Fig 16(b))."""
    qs = plan.qs_d.reshape((plan.t,) + (1,) * (residues.ndim - 1))
    y = (residues * plan.qi_tilde_d.reshape(qs.shape)) % qs  # (t, ...)
    star = plan.qi_star_limbs_d  # (t, L)
    star_b = star.reshape((plan.t,) + (1,) * (residues.ndim - 1) + (plan.L,))
    contrib = y[..., None] * star_b  # (t, ..., L), products < 2^58
    acc = contrib.sum(axis=0)  # (..., L), < t * 2^58
    acc = bigint.carry_normalize(acc, plan.w)
    q_limbs = plan.q_limbs_d
    q_b = q_limbs.reshape((1,) * (acc.ndim - 1) + (plan.L,))
    return bigint.mod_by_subtraction(acc, jnp.broadcast_to(q_b, acc.shape), plan.w, plan.t - 1)


def compose_conventional(residues: jnp.ndarray, plan: RnsPlan) -> jnp.ndarray:
    """Baseline Fig 16(a): multiply residues by the full-width constants
    e_i = q_i^ * q_i~ mod q and reduce the sum mod q by subtraction.  Kept
    as the comparison target for the Table V benchmark (the 'expensive'
    variant differs in *datapath cost*, not in this functional model —
    op-count accounting happens in benchmarks/postprocess.py)."""
    # e_i as limbs, wide enough for the un-reduced sum (< t * q * 2^v)
    Lw = max(plan.L, -(-(plan.q.bit_length() + plan.v + 8) // plan.w))
    e = [
        (int(plan.qi_tilde[i]) * (plan.q // int(plan.qs[i]))) % plan.q
        for i in range(plan.t)
    ]
    e_limbs = bigint.ints_to_limbs(e, plan.w, Lw)  # (t, Lw)
    # residue (31b) x limb (28b) products, accumulated
    e_b = jnp.asarray(e_limbs).reshape(
        (plan.t,) + (1,) * (residues.ndim - 1) + (Lw,)
    )
    contrib = residues[..., None] * e_b
    padded = bigint.carry_normalize(contrib.sum(axis=0), plan.w)
    # each term < q * 2^v; reduce with a subtraction ladder over shifted q
    # (host-precomputed powers-of-two multiples), modeling the wide
    # reduction over q that the paper's Fig 16(b) eliminates.
    q_mults = [plan.q << s for s in range(plan.v + plan.t.bit_length(), -1, -1)]
    for qm in q_mults:
        if qm.bit_length() > Lw * plan.w:
            continue
        qm_limbs = jnp.asarray(bigint.int_to_limbs(qm, plan.w, Lw))
        qm_b = jnp.broadcast_to(
            qm_limbs.reshape((1,) * (padded.ndim - 1) + (Lw,)), padded.shape
        )
        padded = bigint.cond_sub(padded, qm_b, plan.w)
    return padded[..., : plan.L]
