"""Per-arch reduced-config smoke tests + model behaviour tests
(decode/prefill consistency, SSD chunked-vs-recurrent equivalence, MoE)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import model as M
from repro.models import ssm

ARCH_IDS = sorted(registry.ARCHS)


def _smoke_batch(cfg: ModelConfig, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.family == "encdec":
        batch["enc_embeddings"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)
        )
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    elif cfg.frontend:
        batch["embeddings"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)
        )
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = registry.get(arch).reduced()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        batch = _smoke_batch(cfg)
        logits = M.forward(params, cfg, batch)
        assert logits.shape == (2, 16, cfg.vocab)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    def test_train_step_reduces_loss_shape(self, arch):
        """One SGD step on CPU: loss is finite and grads flow."""
        cfg = registry.get(arch).reduced()
        params = M.init_params(jax.random.PRNGKey(1), cfg)
        batch = _smoke_batch(cfg, seed=1)

        def loss_fn(p):
            logits = M.forward(p, cfg, batch, remat=True)
            lab = batch["labels"]
            lp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(lp, lab[..., None], axis=-1)
            return nll.mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert bool(jnp.isfinite(loss))
        gnorm = jnp.sqrt(
            sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
        )
        assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
        # one step changes the params
        new = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
        l2 = loss_fn(new)
        assert bool(jnp.isfinite(l2))


@pytest.mark.parametrize(
    "arch", [a for a in ARCH_IDS if registry.get(a).family != "encdec"]
)
def test_decode_matches_prefill(arch):
    """Greedy decode token-by-token == teacher-forced forward logits."""
    cfg = registry.get(arch).reduced()
    if cfg.frontend:
        pytest.skip("frontend stubs decode over embeddings; covered separately")
    if cfg.n_experts:
        # dropping-MoE capacity competition is per-call; equality requires
        # a no-drop capacity factor (documented semantic of dropping MoE)
        import dataclasses

        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    B, S = 2, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full = M.forward(params, cfg, {"tokens": tokens})
    cache = M.init_cache(cfg, B, max_len=S)
    outs = []
    for s in range(S):
        logits, cache = M.decode_step(params, cfg, cache, {"tokens": tokens[:, s : s + 1]})
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full), rtol=2e-2, atol=2e-2
    )


def test_encdec_decode_matches_forward():
    cfg = registry.get("seamless-m4t-medium").reduced()
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    B, Se, Sd = 2, 12, 6
    enc = jnp.asarray(rng.normal(size=(B, Se, cfg.d_model)).astype(np.float32))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, Sd)), jnp.int32)
    full = M.forward(params, cfg, {"enc_embeddings": enc, "tokens": toks})
    from repro.models import encdec

    memory = encdec.encode(params, cfg, enc)
    cache = M.init_cache(cfg, B, max_len=Sd, enc_len=Se)
    cache = encdec.prefill_cross(params, cfg, cache, memory)
    outs = []
    for s in range(Sd):
        logits, cache = encdec.decode_step(params, cfg, cache, {"tokens": toks[:, s : s + 1]})
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-2, atol=2e-2)


class TestSsd:
    def test_chunked_equals_recurrent(self):
        """The SSD chunked algorithm == naive per-step recurrence."""
        B, S, H, P, N = 2, 32, 3, 8, 16
        rng = np.random.default_rng(0)
        X = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
        dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(B, S, H)).astype(np.float32))
        A = -jnp.asarray(rng.uniform(0.5, 1.5, size=(H,)).astype(np.float32))
        Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
        Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
        Yc, hc = ssm._ssd_chunked(X, dt, A, Bm, Cm, chunk=8)
        h = jnp.zeros((B, H, P, N))
        ys = []
        for s in range(S):
            h, y = ssm._ssd_recurrent_step(h, X[:, s], dt[:, s], A, Bm[:, s], Cm[:, s])
            ys.append(y)
        Yr = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(Yc), np.asarray(Yr), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(hc), np.asarray(h), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("chunk", [4, 8, 16, 32])
    def test_chunk_size_invariance(self, chunk):
        B, S, H, P, N = 1, 32, 2, 4, 8
        rng = np.random.default_rng(chunk)
        X = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
        dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(B, S, H)).astype(np.float32))
        A = -jnp.ones((H,), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
        Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
        Y1, _ = ssm._ssd_chunked(X, dt, A, Bm, Cm, chunk=chunk)
        Y2, _ = ssm._ssd_chunked(X, dt, A, Bm, Cm, chunk=S)
        np.testing.assert_allclose(np.asarray(Y1), np.asarray(Y2), rtol=1e-4, atol=1e-4)


class TestMoe:
    def test_moe_routes_and_keeps_shape(self):
        cfg = registry.get("dbrx-132b").reduced()
        key = jax.random.PRNGKey(0)
        p = L.moe_init(key, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
        out = L.moe_apply(p, x, cfg)
        assert out.shape == x.shape
        assert bool(jnp.isfinite(out).all())

    def test_moe_capacity_drops_dont_nan(self):
        cfg = registry.get("dbrx-132b").reduced()
        import dataclasses

        cfg = dataclasses.replace(cfg, capacity_factor=0.25)  # force drops
        p = L.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
        out = L.moe_apply(p, x, cfg)
        assert bool(jnp.isfinite(out).all())

    def test_top1_vs_topk_paths(self):
        cfg = registry.get("llama4-maverick-400b-a17b").reduced()
        p = L.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model), jnp.float32)
        out = L.moe_apply(p, x, cfg)
        assert out.shape == x.shape


class TestAttentionVariants:
    def test_sliding_window_masks_past(self):
        cfg = registry.get("gemma2-2b").reduced()
        p = L.attention_init(jax.random.PRNGKey(0), cfg)
        B, S = 1, 32
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        out_w, _ = L.attention_apply(p, x, cfg, pos, layer_window=jnp.int32(4))
        out_g, _ = L.attention_apply(p, x, cfg, pos, layer_window=jnp.int32(0))
        # early tokens agree (window covers full history), late ones differ
        assert np.allclose(np.asarray(out_w[:, :3]), np.asarray(out_g[:, :3]), atol=1e-3)
        assert not np.allclose(np.asarray(out_w[:, -1]), np.asarray(out_g[:, -1]), atol=1e-4)

    def test_mrope_equals_rope_for_text(self):
        """With equal position streams M-RoPE degenerates to RoPE."""
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (2, 8))
        a = L.apply_rope(x, pos, 10_000.0, sections=())
        b = L.apply_rope(x, pos, 10_000.0, sections=(4, 6, 6))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)

    def test_softcap_bounds_logits(self):
        cfg = registry.get("gemma2-2b").reduced()
        assert cfg.logit_softcap > 0
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        batch = _smoke_batch(cfg)
        logits = M.forward(params, cfg, batch)
        assert float(jnp.max(jnp.abs(logits))) <= cfg.logit_softcap + 1e-3
