"""Serving launcher: batched decode engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs import registry
from repro.models import model as M
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch_slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab, size=rng.integers(2, 8)).astype(np.int32)
        for _ in range(args.slots)
    ]
    outs = eng.generate(prompts, max_new=args.max_new)
    for i, o in enumerate(outs):
        print(f"req {i}: {len(o)} tokens: {o[:8]}...")


if __name__ == "__main__":
    main()
