"""Metric exporters: Prometheus text format 0.0.4 and JSON, plus a
strict parser the ``obs-smoke`` CI gate uses to validate exporter
output without a Prometheus binary in the container.

The Prometheus rendering follows the exposition-format rules that
matter for scrapability: one ``# HELP``/``# TYPE`` pair per family,
histogram families exposed as cumulative ``_bucket{le=...}`` series
(including the mandatory ``le="+Inf"``) plus ``_sum``/``_count``,
label values escaped (``\\\\``, ``\\"``, ``\\n``), counters suffixed
``_total`` by naming convention (the registry enforces nothing here —
naming is DESIGN.md §12's job).
"""
from __future__ import annotations

import math
import re
from typing import Any

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    registry as default_registry,
)

__all__ = [
    "parse_prometheus",
    "to_json",
    "to_prometheus",
]


def _esc(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(names: tuple[str, ...], values: tuple[str, ...],
                extra: tuple[tuple[str, str], ...] = ()) -> str:
    pairs = [f'{n}="{_esc(v)}"' for n, v in zip(names, values)]
    pairs += [f'{n}="{_esc(v)}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def _fmt_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def to_prometheus(reg: MetricsRegistry | None = None) -> str:
    """Render a registry in Prometheus text exposition format 0.0.4."""
    reg = reg if reg is not None else default_registry()
    out: list[str] = []
    for m in reg.metrics():
        out.append(f"# HELP {m.name} {_esc(m.help) if m.help else m.name}")
        out.append(f"# TYPE {m.name} {m.kind}")
        if isinstance(m, Histogram):
            for key, child in m.children():
                cum = 0
                for i, bound in enumerate(child.bounds):
                    cum += child.counts[i]
                    lbl = _fmt_labels(
                        m.labelnames, key, (("le", _fmt_value(bound)),)
                    )
                    out.append(f"{m.name}_bucket{lbl} {cum}")
                cum += child.counts[-1]
                lbl = _fmt_labels(m.labelnames, key, (("le", "+Inf"),))
                out.append(f"{m.name}_bucket{lbl} {cum}")
                base = _fmt_labels(m.labelnames, key)
                out.append(f"{m.name}_sum{base} {_fmt_value(child.sum)}")
                out.append(f"{m.name}_count{base} {child.count}")
        elif isinstance(m, (Counter, Gauge)):
            for key, child in m.children():
                lbl = _fmt_labels(m.labelnames, key)
                out.append(f"{m.name}{lbl} {_fmt_value(child.value)}")
    return "\n".join(out) + ("\n" if out else "")


def to_json(reg: MetricsRegistry | None = None) -> dict[str, Any]:
    """Registry contents as one JSON-ready dict — the ``"obs"`` record
    merged into ``BENCH_ci.json`` and the ``--json`` CLI output."""
    reg = reg if reg is not None else default_registry()
    families: list[dict[str, Any]] = []
    for m in reg.metrics():
        fam: dict[str, Any] = {
            "name": m.name,
            "kind": m.kind,
            "help": m.help,
            "labels": list(m.labelnames),
            "series": [],
        }
        for key, child in m.children():
            series: dict[str, Any] = {
                "labels": dict(zip(m.labelnames, key)),
            }
            if isinstance(m, Histogram):
                series.update(
                    count=child.count,
                    sum=child.sum,
                    bounds=list(child.bounds),
                    counts=list(child.counts),
                    p50=child.quantile(0.50),
                    p99=child.quantile(0.99),
                )
            else:
                series["value"] = float(child.value)
            fam["series"].append(series)
        families.append(fam)
    return {"schema": "repro.obs/v1", "families": families}


# --------------------------------------------------------------------------
# validating parser (the CI gate's stand-in for a real scraper)
# --------------------------------------------------------------------------

_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*")
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?"
    r"\s+(?P<value>[^\s]+)"
    r"(?:\s+(?P<ts>-?\d+))?$"
)
_LABEL_RE = re.compile(
    r'\s*(?P<name>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<value>(?:[^"\\]|\\.)*)"\s*(,|$)'
)


def _parse_value(s: str) -> float:
    if s == "+Inf":
        return math.inf
    if s == "-Inf":
        return -math.inf
    if s == "NaN":
        return math.nan
    return float(s)  # raises ValueError on garbage


def parse_prometheus(text: str) -> dict[str, Any]:
    """Parse (and thereby validate) Prometheus text format.

    Returns ``{family: {"type": ..., "samples": [(name, labels, value)]}}``
    and raises ``ValueError`` with the offending line number on any
    malformed line, unknown sample for a typed family, non-cumulative
    histogram buckets, or a histogram family missing its ``+Inf``
    bucket / ``_sum`` / ``_count`` series — the failure modes that make
    real scrapers drop an exposition."""
    families: dict[str, dict[str, Any]] = {}

    def fam(name: str) -> dict[str, Any]:
        return families.setdefault(name, {"type": None, "help": None,
                                          "samples": []})

    for ln, raw in enumerate(text.split("\n"), 1):
        line = raw.rstrip()
        if not line:
            continue
        if line.startswith("# HELP "):
            parts = line.split(None, 3)
            if len(parts) < 3 or not _NAME_RE.fullmatch(parts[2]):
                raise ValueError(f"line {ln}: malformed HELP: {raw!r}")
            fam(parts[2])["help"] = parts[3] if len(parts) > 3 else ""
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                "counter", "gauge", "histogram", "summary", "untyped"
            ):
                raise ValueError(f"line {ln}: malformed TYPE: {raw!r}")
            f = fam(parts[2])
            if f["samples"]:
                raise ValueError(
                    f"line {ln}: TYPE for {parts[2]} after its samples"
                )
            f["type"] = parts[3]
            continue
        if line.startswith("#"):
            continue  # comment
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {ln}: malformed sample: {raw!r}")
        labels: dict[str, str] = {}
        body = m.group("labels")
        if body is not None:
            pos = 0
            while pos < len(body):
                lm = _LABEL_RE.match(body, pos)
                if lm is None:
                    raise ValueError(
                        f"line {ln}: malformed labels: {{{body}}}"
                    )
                labels[lm.group("name")] = lm.group("value")
                pos = lm.end()
        try:
            value = _parse_value(m.group("value"))
        except ValueError as e:
            raise ValueError(
                f"line {ln}: bad sample value {m.group('value')!r}"
            ) from e
        name = m.group("name")
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            stem = name[: -len(suffix)] if name.endswith(suffix) else None
            if stem and families.get(stem, {}).get("type") == "histogram":
                base = stem
                break
        f = families.get(base)
        if f is None:
            f = fam(base)
        elif f["type"] == "histogram" and base == name:
            raise ValueError(
                f"line {ln}: bare sample {name!r} for histogram family"
            )
        f["samples"].append((name, labels, value))

    # histogram family structural checks
    for name, f in families.items():
        if f["type"] != "histogram":
            continue
        series: dict[tuple[tuple[str, str], ...], list[tuple[float, float]]]
        series = {}
        sums, counts = set(), set()
        for sname, labels, value in f["samples"]:
            key = tuple(sorted(
                (k, v) for k, v in labels.items() if k != "le"
            ))
            if sname == name + "_bucket":
                if "le" not in labels:
                    raise ValueError(
                        f"{name}: _bucket sample without le label"
                    )
                series.setdefault(key, []).append(
                    (_parse_value(labels["le"]), value)
                )
            elif sname == name + "_sum":
                sums.add(key)
            elif sname == name + "_count":
                counts.add(key)
        for key, buckets in series.items():
            if not any(math.isinf(le) and le > 0 for le, _ in buckets):
                raise ValueError(f"{name}{dict(key)}: missing +Inf bucket")
            ordered = sorted(buckets, key=lambda b: b[0])
            if any(b1[1] > b2[1] for b1, b2 in zip(ordered, ordered[1:])):
                raise ValueError(
                    f"{name}{dict(key)}: bucket counts not cumulative"
                )
            if key not in sums or key not in counts:
                raise ValueError(f"{name}{dict(key)}: missing _sum/_count")
    return families
