"""Paper Table IV: residual-coefficient computation (pre-processing) —
prior design (Fig 11a: per-segment v x v multiplier + Barrett each) vs the
proposed SAU/Alg-2 design.  FPGA LUTs aren't measurable here; we report
(a) the datapath op-count proxy per coefficient per channel and (b)
measured wall-clock of both jit'd paths.
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import params as params_mod
from repro.core import rns


def op_counts(plan):
    """Per coefficient, per RNS channel."""
    S, tp = plan.seg_count, plan.t_prime
    n_blocks = -(-S // tp)
    prior = {
        "vxv_mults": S - 1,
        "barretts": S - 1,
        "adds": S - 1,
    }
    n_beta_terms = len(plan.beta_terms[0]) + 1  # + the trailing -1
    sau_adds = 0
    sau_barretts = 0
    for rho in range(n_blocks):
        for k in range(1, tp):
            # k SAU applications with a Barrett between each (depth-1 cap)
            sau_adds += k * n_beta_terms
            sau_barretts += max(k - 1, 0) + (1 if k >= 2 else 0)
        sau_barretts += 1  # per-block reduce
    proposed = {
        "vxv_mults": n_blocks - 1,  # one [beta^{t'rho}] mult per extra block
        "barretts": sau_barretts + 1,
        "adds": sau_adds + S - 1,
    }
    return prior, proposed


def run():
    out = []
    p = params_mod.make_params(n=4096, t=6, v=30)
    prior, prop = op_counts(p.plan)
    out.append(
        (
            "tableIV_opcounts_t6_v30",
            0.0,
            f"prior_mults={prior['vxv_mults']} prop_mults={prop['vxv_mults']} "
            f"prior_barretts={prior['barretts']} prop_barretts={prop['barretts']} "
            f"prop_extra_adds={prop['adds'] - prior['adds']}",
        )
    )
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.integers(0, 1 << 30, size=(4096, p.plan.seg_count)))
    f_gen = jax.jit(lambda z: rns.decompose(z, p.plan))
    f_sau = jax.jit(lambda z: rns.decompose_sau(z, p.plan))
    assert np.array_equal(np.asarray(f_gen(z)), np.asarray(f_sau(z)))
    for name, fn in [("generic_mult", f_gen), ("sau_alg2", f_sau)]:
        jax.block_until_ready(fn(z))
        t0 = time.perf_counter()
        for _ in range(10):
            jax.block_until_ready(fn(z))
        us = (time.perf_counter() - t0) / 10 * 1e6
        out.append(
            (f"tableIV_preprocess_{name}", us, "n=4096 coeffs, t=6, v=30 (CPU)")
        )
    return out
