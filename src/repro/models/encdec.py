"""Encoder-decoder backbone (seamless-m4t): n_layers bidirectional encoder
over frontend (audio) embeddings + n_layers causal decoder with
cross-attention.  The speech frontend is a stub per the assignment:
``input_specs`` supplies precomputed frame embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as tfm
from repro.sharding import ctx


def _dec_block_init(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "ln1": L.rmsnorm_init(d),
        "attn": L.attention_init(ks[0], cfg),
        "ln_x": L.rmsnorm_init(d),
        "xattn": L.attention_init(ks[1], cfg),
        "ln2": L.rmsnorm_init(d),
        "ffn": L.mlp_init(ks[2], d, cfg.d_ff),
    }


def init_params(key, cfg: ModelConfig):
    k_e, k_enc, k_dec, k_h = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: tfm.block_init(k, cfg, moe=False))(
        jax.random.split(k_enc, cfg.n_layers)
    )
    dec = jax.vmap(lambda k: _dec_block_init(k, cfg))(
        jax.random.split(k_dec, cfg.n_layers)
    )
    return {
        "embed": L.dense_init(k_e, (cfg.padded_vocab, cfg.d_model), scale=0.02),
        "encoder": enc,
        "decoder": dec,
        "enc_norm": L.rmsnorm_init(cfg.d_model),
        "final_norm": L.rmsnorm_init(cfg.d_model),
        "lm_head": L.dense_init(k_h, (cfg.d_model, cfg.padded_vocab)),
    }


def encode(params, cfg: ModelConfig, enc_embeddings, *, remat: bool = False):
    """enc_embeddings: (B, S_enc, D) from the frontend stub."""
    x = enc_embeddings.astype(L.CDTYPE)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        a, _ = L.attention_apply(
            lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg, positions,
            causal=False,
        )
        x = x + a
        x = x + L.mlp_apply(lp["ffn"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return ctx.constrain(x, "btd"), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_kv(lp, cfg: ModelConfig, memory):
    B, S = memory.shape[:2]
    hk, dh = cfg.n_kv_heads, cfg.head_dim_
    k = (memory @ lp["xattn"]["wk"].astype(L.CDTYPE)).reshape(B, S, hk, dh)
    v = (memory @ lp["xattn"]["wv"].astype(L.CDTYPE)).reshape(B, S, hk, dh)
    return k, v


def _dec_block(lp, x, cfg, positions, memory=None, cross=None, cache=None):
    a, nc = L.attention_apply(
        lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg, positions,
        kv_cache=cache,
    )
    x = x + a
    ck = cross if cross is not None else _cross_kv(lp, cfg, memory)
    xa, _ = L.attention_apply(
        lp["xattn"], L.rmsnorm(lp["ln_x"], x, cfg.norm_eps), cfg, positions,
        cross_kv=ck,
    )
    x = x + xa
    x = x + L.mlp_apply(lp["ffn"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps))
    return ctx.constrain(x, "btd"), nc


def forward(params, cfg: ModelConfig, batch, *, remat: bool = False,
            last_only: bool = False):
    """batch: {"enc_embeddings": (B,S_enc,D), "tokens": (B,S_dec)}."""
    memory = encode(params, cfg, batch["enc_embeddings"], remat=remat)
    x = params["embed"][batch["tokens"]].astype(L.CDTYPE)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        x, _ = _dec_block(lp, x, cfg, positions, memory=memory)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    if last_only:
        x = x[:, -1:]
    return tfm.unembed(params, cfg, x)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    hk, dh = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, hk, dh), L.CDTYPE),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, hk, dh), L.CDTYPE),
        "ck": jnp.zeros((cfg.n_layers, batch, enc_len, hk, dh), L.CDTYPE),
        "cv": jnp.zeros((cfg.n_layers, batch, enc_len, hk, dh), L.CDTYPE),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill_cross(params, cfg: ModelConfig, cache, memory):
    """Precompute per-layer cross-attention K/V from encoder memory."""
    def body(_, lp):
        return None, _cross_kv(lp, cfg, memory)

    _, (ck, cv) = jax.lax.scan(body, None, params["decoder"])
    return {**cache, "ck": ck, "cv": cv}


def decode_step(params, cfg: ModelConfig, cache, batch):
    x = params["embed"][batch["tokens"]].astype(L.CDTYPE)
    B, S = x.shape[:2]
    pos = cache["pos"]
    positions = pos + jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, inp):
        lp, ck, cv, xk, xv = inp
        x, nc = _dec_block(
            lp, x, cfg, positions, cross=(xk, xv),
            cache={"k": ck, "v": cv, "pos": pos},
        )
        return x, (nc["k"], nc["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"], cache["ck"], cache["cv"])
    )
    new_cache = {**cache, "k": nk, "v": nv, "pos": pos + S}
    return tfm.unembed(params, cfg, x), new_cache
