"""Version-compatibility gates, centralized.

One module owns every ``jax``-version switch so the rest of the tree can
use plain imports.  The declared floor is jax >= 0.5 (pyproject + CI
matrix), where ``shard_map`` lives at the top level; the single fallback
below keeps pinned pre-0.5 runtimes (e.g. hermetic eval containers that
cannot pip-install) working and is the only place left to delete when
the last such runtime is gone — the per-call-site try/except shims that
used to live in ``train/aggregation.py`` and ``sharding/ctx.py`` were
folded into this import.
"""
from __future__ import annotations

try:  # jax >= 0.5: promoted out of jax.experimental
    from jax import shard_map
except ImportError:  # pragma: no cover - pre-0.5 pinned runtimes only
    from jax.experimental.shard_map import shard_map

__all__ = ["shard_map"]
