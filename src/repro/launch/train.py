"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 100 --batch 8 --seq 512 [--he-aggregation] [--reduced]

On a real multi-host TPU deployment this process runs per host after
``jax.distributed.initialize()``; the mesh comes from
``mesh.make_production_mesh()`` and the same Trainer drives pjit'd steps.
On this CPU container it runs the 1-device mesh end to end.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import registry
from repro.configs.base import RunConfig
from repro.train import data as data_mod
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--remat-group", type=int, default=1)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    run = RunConfig(
        model=cfg,
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every,
        remat_group=args.remat_group,
        grad_accum_steps=args.grad_accum,
    )
    dc = data_mod.DataConfig(batch=args.batch, seq_len=args.seq)
    trainer = Trainer(run, dc, total_steps=args.steps)
    trainer.train(jax.random.PRNGKey(0), steps=args.steps)


if __name__ == "__main__":
    main()
