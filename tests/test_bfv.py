"""BFV HE layer: enc/dec roundtrip, homomorphic add, ct x pt, ct x ct (ref)."""
import random

import numpy as np
import pytest

import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade property tests to per-test skips, not errors
    from _hypothesis_fallback import given, settings, st

from repro.core import bfv, bfv_ref
from repro.core import polymul as pm


@pytest.fixture(scope="module")
def ctx():
    return bfv.make_context(n=64, t=3, v=30, pt_mod=1 << 16)


@pytest.fixture(scope="module")
def keys(ctx):
    return bfv.keygen(jax.random.PRNGKey(0), ctx)


class TestBfvJax:
    def test_enc_dec_roundtrip(self, ctx, keys):
        rng = np.random.default_rng(0)
        m = jnp.asarray(rng.integers(0, ctx.pt_mod, size=64))
        ct = bfv.encrypt(jax.random.PRNGKey(1), m, keys, ctx)
        got = bfv.decrypt(ct, keys, ctx)
        assert np.array_equal(got, np.asarray(m))

    def test_homomorphic_add(self, ctx, keys):
        rng = np.random.default_rng(1)
        a = rng.integers(0, ctx.pt_mod // 4, size=64)
        b = rng.integers(0, ctx.pt_mod // 4, size=64)
        ca = bfv.encrypt(jax.random.PRNGKey(2), jnp.asarray(a), keys, ctx)
        cb = bfv.encrypt(jax.random.PRNGKey(3), jnp.asarray(b), keys, ctx)
        got = bfv.decrypt(bfv.add(ca, cb, ctx), keys, ctx)
        assert np.array_equal(got, (a + b) % ctx.pt_mod)

    def test_add_many(self, ctx, keys):
        rng = np.random.default_rng(2)
        ms = [rng.integers(0, 255, size=64) for _ in range(8)]
        cts = [
            bfv.encrypt(jax.random.PRNGKey(10 + i), jnp.asarray(m), keys, ctx)
            for i, m in enumerate(ms)
        ]
        got = bfv.decrypt(bfv.add_many(cts, ctx), keys, ctx)
        assert np.array_equal(got, sum(ms) % ctx.pt_mod)

    def test_mul_plain(self, ctx, keys):
        rng = np.random.default_rng(3)
        m = rng.integers(0, 64, size=64)
        w = rng.integers(-4, 5, size=64)
        ct = bfv.encrypt(jax.random.PRNGKey(4), jnp.asarray(m), keys, ctx)
        got = bfv.decrypt(bfv.mul_plain(ct, jnp.asarray(w), ctx), keys, ctx)
        want = np.array(
            pm.schoolbook_negacyclic(
                m.tolist(), [int(x) % ctx.pt_mod for x in w], ctx.pt_mod
            )
        )
        assert np.array_equal(got, want)

    @pytest.mark.slow  # batched host-side bigint decrypt
    def test_batched_encrypt(self, ctx, keys):
        rng = np.random.default_rng(4)
        m = rng.integers(0, 100, size=(3, 64))
        ct = bfv.encrypt(jax.random.PRNGKey(5), jnp.asarray(m), keys, ctx)
        got = bfv.decrypt(ct, keys, ctx)
        assert np.array_equal(got, m)

    def test_noise_budget_positive_and_decreasing(self, ctx, keys):
        rng = np.random.default_rng(5)
        m = rng.integers(0, 16, size=64)
        ct = bfv.encrypt(jax.random.PRNGKey(6), jnp.asarray(m), keys, ctx)
        fresh = bfv.noise_budget_bits(ct, keys, ctx, m)
        assert fresh > 20
        w = rng.integers(-3, 4, size=64)
        ct2 = bfv.mul_plain(ct, jnp.asarray(w), ctx)
        m2 = np.array(
            pm.schoolbook_negacyclic(
                m.tolist(), [int(x) % ctx.pt_mod for x in w], ctx.pt_mod
            )
        )
        after = bfv.noise_budget_bits(ct2, keys, ctx, m2)
        assert after < fresh
        assert after > 0

    @given(st.integers(0, 2**32))
    @settings(max_examples=8, deadline=None)
    def test_additive_homomorphism_property(self, seed):
        ctx = bfv.make_context(n=64, t=3, v=30, pt_mod=1 << 16)
        keys = bfv.keygen(jax.random.PRNGKey(17), ctx)
        rng = np.random.default_rng(seed)
        a = rng.integers(0, 2**14, size=64)
        b = rng.integers(0, 2**14, size=64)
        ca = bfv.encrypt(jax.random.PRNGKey(seed % 1000), jnp.asarray(a), keys, ctx)
        cb = bfv.encrypt(jax.random.PRNGKey(seed % 997 + 1), jnp.asarray(b), keys, ctx)
        got = bfv.decrypt(bfv.add(ca, cb, ctx), keys, ctx)
        assert np.array_equal(got, (a + b) % ctx.pt_mod)


class TestBfvRef:
    @pytest.fixture(scope="class")
    def rctx(self):
        return bfv_ref.make_ref_context(n=32, t=3, v=30, pt_mod=257)

    @pytest.fixture(scope="class")
    def rkeys(self, rctx):
        return bfv_ref.keygen(random.Random(0), rctx)

    def test_roundtrip(self, rctx, rkeys):
        rng = random.Random(1)
        m = [rng.randrange(rctx.pt_mod) for _ in range(rctx.n)]
        ct = bfv_ref.encrypt(rng, m, rkeys, rctx)
        assert bfv_ref.decrypt(ct, rkeys, rctx) == m

    def test_ct_ct_mul_with_relin(self, rctx, rkeys):
        rng = random.Random(2)
        a = [rng.randrange(16) for _ in range(rctx.n)]
        b = [rng.randrange(16) for _ in range(rctx.n)]
        ca = bfv_ref.encrypt(rng, a, rkeys, rctx)
        cb = bfv_ref.encrypt(rng, b, rkeys, rctx)
        prod = bfv_ref.mul(ca, cb, rkeys, rctx)
        got = bfv_ref.decrypt(prod, rkeys, rctx)
        want = pm.schoolbook_negacyclic(a, b, rctx.pt_mod)
        assert got == want

    def test_depth_two(self, rctx, rkeys):
        rng = random.Random(3)
        a = [rng.randrange(4) for _ in range(rctx.n)]
        b = [rng.randrange(4) for _ in range(rctx.n)]
        c = [rng.randrange(4) for _ in range(rctx.n)]
        ca = bfv_ref.encrypt(rng, a, rkeys, rctx)
        cb = bfv_ref.encrypt(rng, b, rkeys, rctx)
        cc = bfv_ref.encrypt(rng, c, rkeys, rctx)
        prod = bfv_ref.mul(bfv_ref.mul(ca, cb, rkeys, rctx), cc, rkeys, rctx)
        got = bfv_ref.decrypt(prod, rkeys, rctx)
        want = pm.schoolbook_negacyclic(
            pm.schoolbook_negacyclic(a, b, rctx.pt_mod), c, rctx.pt_mod
        )
        assert got == want

    def test_jax_and_ref_agree_on_add(self, rctx, rkeys):
        """Cross-check: decrypting a JAX ct with the same math as ref."""
        ctx = bfv.make_context(n=32, t=3, v=30, pt_mod=257)
        keys = bfv.keygen(jax.random.PRNGKey(7), ctx)
        rng = np.random.default_rng(8)
        a = rng.integers(0, 100, size=32)
        ct = bfv.encrypt(jax.random.PRNGKey(8), jnp.asarray(a), keys, ctx)
        assert np.array_equal(bfv.decrypt(ct, keys, ctx), a % 257)
