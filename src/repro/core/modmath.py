"""Single source of truth for modular scalar arithmetic (int64 lanes).

Both datapaths import from here — the pure-jnp reference oracle
(:mod:`repro.core.ntt`, :mod:`repro.core.rns`) and the Pallas kernels
(:mod:`repro.kernels.ntt`, :mod:`repro.kernels.crt`) — so the oracle the
kernels are validated against can never drift from the kernel math.

Two reduction strategies for the butterfly multiply:

* generic ``%`` — correct for any modulus, but lowers to an integer
  divide on every butterfly (the hot-loop cost the paper's Barrett PEs
  exist to avoid);
* precomputed Barrett — ``eps = floor(2^(2b) / q)`` per channel (b =
  bit-length of q), shift/multiply/3-conditional-subtract.  Valid for
  products ``x*y`` with ``x, y < q < 2^31`` and requires
  ``2*(b+1) <= 63`` (b <= 30, the paper's preferred v=30 operating
  point).  The (s1, s2) shift pair is static per configuration; only
  ``eps`` varies per RNS channel, so the same vectorized code serves all
  t channels.

Every helper accepts scalars or broadcastable arrays for ``q`` / ``eps``
so one implementation serves single-modulus, vmapped multi-channel, and
in-kernel (Pallas ref-value) call sites.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# add / sub / halve
# --------------------------------------------------------------------------


def add_mod(x, y, q):
    """(x + y) mod q for x, y in [0, q)."""
    s = x + y
    return jnp.where(s >= q, s - q, s)


def sub_mod(x, y, q):
    """(x - y) mod q for x, y in [0, q)."""
    d = x - y
    return jnp.where(d < 0, d + q, d)


def div2_mod(x, q_half):
    """x * 2^{-1} mod q via paper Eq 24: (x >> 1) + (x & 1) * (q+1)/2.
    Result < q whenever x < q (no reduction needed)."""
    return (x >> 1) + (x & 1) * q_half


# --------------------------------------------------------------------------
# Barrett reduction
# --------------------------------------------------------------------------


def barrett_constants(q: int, c: int, v: int) -> tuple[int, int, int]:
    """Constants for reducing x < 2^c mod q (q of v bits), 63-bit safe.

    q_hat = ((x >> (v-1)) * eps) >> (c - v + 1),  eps = floor(2^c / q).
    Requires 2*(c - v + 1) <= 63.  Quotient undershoots by < 4 =>
    three conditional subtractions complete the reduction.
    """
    assert 2 * (c - v + 1) <= 63, (q, c, v)
    eps = (1 << c) // q
    return eps, v - 1, c - v + 1


def barrett_reduce(x, q, eps, s1: int, s2: int):
    """x mod q for x < 2^c (see barrett_constants). Arrays or scalars."""
    qhat = ((x >> s1) * eps) >> s2
    r = x - qhat * q
    for _ in range(3):
        r = jnp.where(r >= q, r - q, r)
    return r


def mul_barrett_constants(qs) -> tuple[np.ndarray, tuple[int, int]] | tuple[None, None]:
    """Per-channel constants for reducing residue products x*y, x, y < q_i.

    Returns ``(eps, (s1, s2))`` with ``eps`` an int64 array aligned with
    ``qs`` and one static shift pair shared by all channels, or
    ``(None, None)`` when the configuration is outside the 63-bit-safe
    envelope (mixed modulus widths, or q >= 2^31 — those paths keep the
    generic ``%``).
    """
    qs = np.atleast_1d(np.asarray(qs, dtype=np.int64))
    widths = {int(q).bit_length() for q in qs}
    if len(widths) != 1:
        return None, None
    b = widths.pop()
    c = 2 * b
    if 2 * (c - b + 1) > 63:
        return None, None
    eps = np.array([(1 << c) // int(q) for q in qs], dtype=np.int64)
    return eps, (b - 1, b + 1)


def channel_mul_constants(qs):
    """Static per-channel ``(qi, half, eps)`` triples plus the shared
    shift pair, as plain python ints.

    This is the scalar layout kernels that specialize per channel bake
    into their closures (one circuit per RNS channel, paper-style): the
    fused e2e kernel unrolls its channel loop over these, so no scalar
    SMEM blocks are needed.  ``eps`` entries are None outside the
    63-bit-safe Barrett envelope (the butterflies then fall back to
    generic ``%``).
    """
    eps, shifts = mul_barrett_constants(qs)
    qs = np.atleast_1d(np.asarray(qs, dtype=np.int64))
    triples = tuple(
        (int(q), (int(q) + 1) // 2, None if eps is None else int(eps[i]))
        for i, q in enumerate(qs)
    )
    return triples, shifts


def mul_mod(x, y, q, eps=None, shifts: tuple[int, int] | None = None):
    """(x * y) mod q for x, y in [0, q).

    With ``eps``/``shifts`` (from :func:`mul_barrett_constants`,
    broadcastable against x*y) the reduction is the paper's Barrett PE;
    without them it falls back to a generic ``%``.
    """
    p = x * y
    if eps is None:
        return p % q
    s1, s2 = shifts
    return barrett_reduce(p, q, eps, s1, s2)
