"""The crypto serving subsystem: shape-bucketed continuous batching
(PolymulEngine), the mesh-sharded cascade (`model` x `data` shard_map
with plan tables resident per-shard), and the crypto partition rules.

Mesh tests run on REAL 4-device host meshes — conftest.py forces
``--xla_force_host_platform_device_count=4`` before jax initializes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro
from repro import api
from repro.core import polymul as pm
from repro.serve.crypto_engine import (
    PolymulEngine,
    negacyclic_mul_sharded,
    polymul_sharded,
)
from repro.sharding import partition


def _rand_segments(pl, rng, batch=None):
    shape = (pl.n, pl.config.seg_count)
    if batch is not None:
        shape = (batch,) + shape
    return (
        rng.integers(0, 1 << pl.v, size=shape),
        rng.integers(0, 1 << pl.v, size=shape),
    )


def _rand_residues(pl, rng, batch):
    return jnp.asarray(
        np.stack(
            [
                rng.integers(0, int(q), size=(batch, pl.n))
                for q in pl.params.plan.qs
            ]
        )
    )


class TestEngineBatching:
    def test_mixed_preset_stream_bit_exact_vs_oracle(self):
        """Both paper presets interleaved through ONE engine: every
        result bit-exact vs the bigint oracle, and exactly one jit
        trace per distinct PlanConfig (the acceptance criterion)."""
        eng = PolymulEngine(batch_slots=4)
        plans = [eng.plan(n=64, t=3, v=30), eng.plan(n=32, t=4, v=45)]
        import random

        r = random.Random(0)
        reqs = []
        for i in range(10):
            pl = plans[i % 2]
            a = [r.randrange(pl.q) for _ in range(pl.n)]
            b = [r.randrange(pl.q) for _ in range(pl.n)]
            za = np.asarray(api.to_segments(pl, a))
            zb = np.asarray(api.to_segments(pl, b))
            reqs.append((pl, a, b, eng.submit(pl, za, zb)))
        eng.run_until_idle()
        for pl, a, b, fut in reqs:
            got = api.from_limbs(pl, fut.result())
            assert got == pm.oracle_multiply(a, b, pl.params)
        assert eng.trace_count == 2  # one compile per distinct config
        assert sorted(
            set(eng.traced_configs), key=lambda c: c.v
        ) == sorted({api.plan_key(p) for p in plans}, key=lambda c: c.v)

    def test_padding_and_slot_reuse_invariants(self):
        """9 requests through 4 slots -> 3 dispatches (4+4+1), 3 padded
        slots total, still ONE trace: the padded batch shape is stable
        across dispatches."""
        rng = np.random.default_rng(1)
        eng = PolymulEngine(batch_slots=4)
        pl = eng.plan(n=64, t=3, v=30)
        futs = []
        want = []
        for _ in range(9):
            za, zb = _rand_segments(pl, rng)
            futs.append(eng.submit(pl, za, zb))
            want.append(
                np.asarray(repro.polymul(pl, jnp.asarray(za), jnp.asarray(zb)))
            )
        assert eng.pending() == 9
        assert eng.step() == 4
        assert eng.pending() == 5
        eng.run_until_idle()
        assert eng.stats["dispatches"] == 3
        assert eng.stats["padded_slots"] == 3
        assert eng.stats["served"] == 9
        assert eng.trace_count == 1
        for fut, w in zip(futs, want):
            assert np.array_equal(fut.result(), w)
            assert fut.latency_s >= 0

    def test_plan_cache_hits(self):
        eng = PolymulEngine()
        a = eng.plan(n=64, t=3, v=30)
        b = eng.plan(n=64, t=3, v=30)
        assert a is b  # cached by plan_key
        c = eng.plan(n=64, t=3, v=30, backend="pallas_fused")
        assert c is not a

    def test_future_unserved_raises(self):
        rng = np.random.default_rng(2)
        eng = PolymulEngine(batch_slots=2)
        pl = eng.plan(n=64, t=3, v=30)
        fut = eng.submit(pl, *_rand_segments(pl, rng))
        assert not fut.done()
        with pytest.raises(RuntimeError, match="not served"):
            fut.result()
        eng.run_until_idle()
        assert fut.done()

    def test_submit_shape_validation(self):
        eng = PolymulEngine()
        pl = eng.plan(n=64, t=3, v=30)
        bad = np.zeros((32, pl.config.seg_count), np.int64)
        ok = np.zeros((64, pl.config.seg_count), np.int64)
        with pytest.raises(ValueError, match="expected za segments"):
            eng.submit(pl, bad, ok)

    def test_oracle_width_requests_served_eagerly(self):
        """v > 46 buckets run the host oracle: no tracing, no padding,
        results still exact (vs the schoolbook)."""
        import random

        r = random.Random(3)
        eng = PolymulEngine(batch_slots=4)
        pl = eng.plan(n=32, t=2, v=50)
        a = [r.randrange(pl.q) for _ in range(pl.n)]
        b = [r.randrange(pl.q) for _ in range(pl.n)]
        fut = eng.submit(
            pl,
            np.asarray(api.to_segments(pl, a)),
            np.asarray(api.to_segments(pl, b)),
        )
        eng.run_until_idle()
        assert api.from_limbs(pl, fut.result()) == pm.schoolbook_negacyclic(
            a, b, pl.q
        )
        assert eng.trace_count == 0
        assert eng.stats["padded_slots"] == 0

    def test_execute_hook_and_plan_key(self):
        rng = np.random.default_rng(4)
        pl = repro.plan(n=64, t=3, v=30)
        assert api.plan_key(pl) == pl.config
        za, zb = _rand_segments(pl, rng, batch=2)
        want = np.asarray(repro.polymul(pl, jnp.asarray(za), jnp.asarray(zb)))
        got = api.execute(pl, jnp.asarray(za), jnp.asarray(zb))
        assert np.array_equal(np.asarray(got), want)
        # donating twin: operands are consumed, result identical
        got_d = api.execute(
            pl, jnp.asarray(za), jnp.asarray(zb), donate=True
        )
        assert np.array_equal(np.asarray(got_d), want)


class TestCryptoPartitionRules:
    def test_polymul_specs_layout(self, host_mesh_4):
        pl = repro.plan(n=64, t=6, v=30)
        specs = partition.polymul_specs(host_mesh_4, pl)
        assert specs["segments"] == P(("data",), None, None)
        assert specs["residues"] == P("model", ("data",), None)
        assert specs["limbs"] == P(("data",), None, None)

    def test_polymul_specs_nondivisible_channel_fallback(self, host_mesh_4):
        pl = repro.plan(n=64, t=3, v=30)  # 3 % 2 != 0 -> replicate channels
        specs = partition.polymul_specs(host_mesh_4, pl)
        assert specs["residues"] == P(None, ("data",), None)

    def test_plan_leaf_specs_channel_major(self, host_mesh_4):
        pl = repro.plan(n=64, t=6, v=30)
        specs = partition.plan_leaf_specs(host_mesh_4, pl)
        for name, leaf in pl.consts.items():
            if name == "rns_q_limbs":
                assert specs[name] == P(*([None] * leaf.ndim)), name
            else:
                assert specs[name][0] == "model", name
                assert len(specs[name]) == leaf.ndim

    def test_plan_tables_resident_per_shard(self, host_mesh_4):
        """device_put with the leaf shardings leaves each model shard
        holding exactly its channels' tables (t/2 rows per shard on the
        2-way model axis) — 'plan tables resident per-shard'."""
        pl = repro.plan(n=64, t=6, v=30)
        consts = jax.device_put(
            pl.consts, partition.plan_leaf_shardings(host_mesh_4, pl)
        )
        fwd = consts["ntt_fwd"]  # (t, n)
        assert not fwd.sharding.is_fully_replicated
        shard_shapes = {s.data.shape for s in fwd.addressable_shards}
        assert shard_shapes == {(3, 64)}
        assert consts["rns_q_limbs"].sharding.is_fully_replicated


class TestMeshShardedCascade:
    def test_model_axis_shard_map_bit_exact(self, host_mesh_4):
        """The acceptance criterion: the model-axis shard_map path of
        negacyclic_mul is bit-exact vs the single-device path."""
        rng = np.random.default_rng(5)
        pl = repro.plan(n=64, t=6, v=30)
        a = _rand_residues(pl, rng, batch=4)
        b = _rand_residues(pl, rng, batch=4)
        want = np.asarray(repro.negacyclic_mul(pl, a, b))
        got = negacyclic_mul_sharded(pl, a, b, mesh=host_mesh_4)
        assert np.array_equal(np.asarray(got), want)

    def test_sharded_cascade_reads_leaves_not_constants(self, host_mesh_4):
        """int64 leaves threaded, not jit constants: mutating a plan's
        twiddle leaf MUST change the sharded result — if the kernels
        bound tables from the static params, this would be a no-op."""
        rng = np.random.default_rng(6)
        pl = repro.plan(n=64, t=6, v=30)
        a = _rand_residues(pl, rng, batch=2)
        b = _rand_residues(pl, rng, batch=2)
        want = np.asarray(negacyclic_mul_sharded(pl, a, b, mesh=host_mesh_4))
        broken_consts = dict(pl.consts)
        broken_consts["ntt_fwd"] = (
            broken_consts["ntt_fwd"] ^ 1
        )  # flip low bits
        broken = api.Plan(
            config=pl.config, params=pl.params, consts=broken_consts
        )
        got = np.asarray(
            negacyclic_mul_sharded(broken, a, b, mesh=host_mesh_4)
        )
        assert not np.array_equal(got, want)

    def test_polymul_sharded_jit_bit_exact(self, host_mesh_4):
        rng = np.random.default_rng(7)
        pl = repro.plan(n=64, t=6, v=30)
        za, zb = _rand_segments(pl, rng, batch=4)
        za, zb = jnp.asarray(za), jnp.asarray(zb)
        want = np.asarray(repro.polymul(pl, za, zb))
        fn = jax.jit(
            lambda p, x, y: polymul_sharded(p, x, y, mesh=host_mesh_4)
        )
        assert np.array_equal(np.asarray(fn(pl, za, zb)), want)

    def test_sharded_rejects_bad_configs(self, host_mesh_4):
        rng = np.random.default_rng(8)
        pl = repro.plan(n=64, t=3, v=30)  # 3 channels % 2-way model != 0
        a = _rand_residues(pl, rng, batch=2)
        with pytest.raises(ValueError, match="do not divide the model"):
            negacyclic_mul_sharded(pl, a, a, mesh=host_mesh_4)
        # wide plans now shard (see TestWideMeshSharding in
        # test_sharding.py); only the host-bigint oracle width is refused
        orc = repro.plan(n=32, t=2, v=50)
        res = jnp.zeros((4, 2, 32), jnp.int64)
        with pytest.raises(ValueError, match="int64/wide-width plans only"):
            negacyclic_mul_sharded(orc, res, res, mesh=host_mesh_4)
        pl6 = repro.plan(n=64, t=6, v=30)
        odd = _rand_residues(pl6, rng, batch=3)  # 3 % data-size 2 != 0
        with pytest.raises(ValueError, match="does not divide the data"):
            negacyclic_mul_sharded(pl6, odd, odd, mesh=host_mesh_4)

    def test_engine_mesh_mode_end_to_end(self, host_mesh_4):
        rng = np.random.default_rng(9)
        eng = PolymulEngine(batch_slots=4, mesh=host_mesh_4)
        pl = eng.plan(n=64, t=6, v=30)
        futs, want = [], []
        for _ in range(6):
            za, zb = _rand_segments(pl, rng)
            futs.append(eng.submit(pl, za, zb))
            want.append(
                np.asarray(repro.polymul(pl, jnp.asarray(za), jnp.asarray(zb)))
            )
        eng.run_until_idle()
        for fut, w in zip(futs, want):
            assert np.array_equal(fut.result(), w)
        assert eng.trace_count == 1
        assert eng.stats["dispatches"] == 2
        assert eng.stats["padded_slots"] == 2

    def test_engine_mesh_mode_rejects_nonsharding_slots(self, host_mesh_4):
        with pytest.raises(ValueError, match="batch_slots"):
            PolymulEngine(batch_slots=3, mesh=host_mesh_4)
        eng = PolymulEngine(batch_slots=4, mesh=host_mesh_4)
        orc = repro.plan(n=32, t=2, v=50)
        z = np.zeros((32, orc.config.seg_count), np.int64)
        with pytest.raises(ValueError, match="int64/wide-width plans only"):
            eng.submit(orc, z, z)

    def test_engine_mesh_mode_rejects_indivisible_t_at_submit(
        self, host_mesh_4
    ):
        """A config that could only fail at trace time would lose its
        already-popped requests — the engine must refuse it at submit
        (the queue stays intact, no future is ever orphaned)."""
        eng = PolymulEngine(batch_slots=4, mesh=host_mesh_4)
        pl = repro.plan(n=64, t=3, v=30)  # 3 % 2-way model != 0
        z = np.zeros((64, pl.config.seg_count), np.int64)
        with pytest.raises(ValueError, match="do not divide"):
            eng.submit(pl, z, z)
        assert eng.pending() == 0
        assert eng.stats["submitted"] == 0


class TestFailureSemantics:
    """PR 8: the engine's robustness contract — no request is ever lost,
    every future resolves exactly once with a value or a typed error."""

    def _mk(self, pl, rng):
        shape = (pl.n, pl.config.seg_count)
        return (
            rng.integers(0, 1 << pl.v, size=shape),
            rng.integers(0, 1 << pl.v, size=shape),
        )

    def test_dispatch_failure_requeues_not_loses(self):
        """THE regression for the request-loss bug: a dispatch that
        raises must leave its popped requests requeued (futures still
        pending and eventually served), not dropped with unresolvable
        futures."""
        rng = np.random.default_rng(0)
        eng = PolymulEngine(batch_slots=4, backoff_base_s=1e-4)
        pl = eng.plan(n=64, t=3, v=30)
        raw = eng.executor
        boom = {"left": 1}

        def flaky(p, za, zb):
            if boom["left"] > 0:
                boom["left"] -= 1
                raise RuntimeError("transient device fault")
            return raw(p, za, zb)

        eng.executor = flaky
        reqs = [self._mk(pl, rng) for _ in range(3)]
        futs = [eng.submit(pl, za, zb) for za, zb in reqs]
        assert eng.step() == 0  # failed dispatch resolves nothing...
        assert eng.pending() == 3  # ...and loses nothing
        assert all(not f.done() for f in futs)
        eng.run_until_idle()
        for f, (za, zb) in zip(futs, reqs):
            assert f.exception() is None
            want = np.asarray(api.polymul(pl, za[None], zb[None]))[0]
            assert np.array_equal(f.result(), want)
        assert eng.stats["retried"] == 3
        assert eng.stats["dispatch_failures"] == 1
        assert eng.stats["served"] == 3

    def test_retries_exhausted_fails_typed(self):
        from repro.errors import BackendFailedError, EngineError

        rng = np.random.default_rng(1)
        eng = PolymulEngine(
            batch_slots=2, max_retries=2, breaker_threshold=100,
            backoff_base_s=1e-4,
        )
        pl = eng.plan(n=64, t=3, v=30)

        def dead(p, za, zb):
            raise RuntimeError("hard fault")

        eng.executor = dead
        fut = eng.submit(pl, *self._mk(pl, rng))
        eng.run_until_idle()
        exc = fut.exception()
        assert isinstance(exc, BackendFailedError)
        assert isinstance(exc, EngineError)
        assert exc.attempts == 3  # first attempt + max_retries
        assert isinstance(exc.__cause__, RuntimeError)
        with pytest.raises(BackendFailedError):
            fut.result()
        assert fut.state == "FAILED"
        assert eng.stats["failed"] == 1
        assert eng.stats["served"] == 0

    def test_breaker_degrades_bit_exact_and_recovers(self):
        """Consecutive e2e failures open the bucket's breaker onto the
        pallas fallback (same n/t/v -> bit-exact), and the post-cooldown
        probe restores the original backend."""
        import time as _time

        rng = np.random.default_rng(2)
        eng = PolymulEngine(
            batch_slots=2, max_retries=6, breaker_threshold=2,
            breaker_cooldown_s=0.05, backoff_base_s=1e-4,
        )
        pl = eng.plan(n=64, t=3, v=30, backend="pallas_fused_e2e")
        raw = eng.executor

        def e2e_down(p, za, zb):
            if api.plan_key(p).backend == "pallas_fused_e2e":
                raise RuntimeError("fused-e2e kernel fault")
            return raw(p, za, zb)

        eng.executor = e2e_down
        za, zb = self._mk(pl, rng)
        fut = eng.submit(pl, za, zb)
        eng.run_until_idle()
        snap = eng.snapshot()
        assert snap["breaker_opened"] == 1
        assert snap["degraded_buckets"] == 1
        assert list(snap["bucket_backends"].values()) == ["pallas"]
        want = np.asarray(api.polymul(pl, za[None], zb[None]))[0]
        assert np.array_equal(fut.result(), want)  # degraded, bit-exact

        eng.executor = raw  # backend "repaired"
        _time.sleep(0.06)  # past the cool-down
        fut2 = eng.submit(pl, *self._mk(pl, rng))
        eng.run_until_idle()
        snap = eng.snapshot()
        assert snap["probes"] >= 1
        assert snap["breaker_recovered"] == 1
        assert snap["degraded_buckets"] == 0
        assert list(snap["bucket_backends"].values()) == [
            "pallas_fused_e2e"
        ]
        assert fut2.exception() is None

    def test_deadline_shed_typed_never_dropped(self):
        from repro.errors import DeadlineExceededError

        rng = np.random.default_rng(3)
        eng = PolymulEngine(batch_slots=2)
        pl = eng.plan(n=64, t=3, v=30)
        # dead on arrival: shed at submit
        doa = eng.submit(pl, *self._mk(pl, rng), deadline=0.0)
        assert doa.done()
        assert isinstance(doa.exception(), DeadlineExceededError)
        assert doa.exception().request_seq is not None
        # expires while queued: shed at the next step
        import time as _time

        late = eng.submit(pl, *self._mk(pl, rng), deadline=0.005)
        _time.sleep(0.01)
        eng.step()
        assert isinstance(late.exception(), DeadlineExceededError)
        assert late.exception().late_s > 0
        assert eng.stats["shed"] == 2
        assert eng.stats["served"] == 0

    def test_backpressure_blocks_and_rejects(self):
        from repro.errors import QueueFullError

        rng = np.random.default_rng(4)
        eng = PolymulEngine(batch_slots=2, max_pending=2)
        pl = eng.plan(n=64, t=3, v=30)
        f1 = eng.submit(pl, *self._mk(pl, rng))
        f2 = eng.submit(pl, *self._mk(pl, rng))
        assert eng.try_submit(pl, *self._mk(pl, rng)) is None
        with pytest.raises(QueueFullError) as ei:
            eng.submit(pl, *self._mk(pl, rng), timeout=0.02)
        assert ei.value.queue_depth == 2
        assert ei.value.max_pending == 2
        assert eng.stats["rejected"] == 2
        eng.run_until_idle()
        assert eng.try_submit(pl, *self._mk(pl, rng)) is not None
        eng.run_until_idle()
        assert f1.done() and f2.done()

    def test_edf_orders_across_buckets_and_priority_ties(self):
        """EDF: the tighter-deadline bucket dispatches first even when
        the other bucket's request arrived earlier; among equal
        deadlines, higher priority wins."""
        rng = np.random.default_rng(5)
        eng = PolymulEngine(batch_slots=1)
        pl_a = eng.plan(n=64, t=3, v=30)
        pl_b = eng.plan(n=32, t=4, v=45)
        slow = eng.submit(pl_a, *self._mk(pl_a, rng), deadline=60.0)
        fast = eng.submit(pl_b, *self._mk(pl_b, rng), deadline=5.0)
        eng.step()
        assert fast.done() and not slow.done()
        eng.run_until_idle()
        # priority ties within one bucket at equal (absent) deadlines
        lo = eng.submit(pl_a, *self._mk(pl_a, rng), priority=0)
        hi = eng.submit(pl_a, *self._mk(pl_a, rng), priority=5)
        eng.step()
        assert hi.done() and not lo.done()
        eng.run_until_idle()

    def test_future_lifecycle_and_latency_stats(self):
        rng = np.random.default_rng(6)
        eng = PolymulEngine(batch_slots=2)
        pl = eng.plan(n=64, t=3, v=30)
        fut = eng.submit(pl, *self._mk(pl, rng))
        assert fut.state == "PENDING" and not fut.done()
        with pytest.raises(RuntimeError, match="not served"):
            fut.result()
        with pytest.raises(TimeoutError):
            fut.result(timeout=0.01)
        eng.run_until_idle()
        assert fut.state == "DONE" and fut.done()
        assert fut.exception() is None
        assert fut.latency_s >= 0
        assert fut.dispatch_index == 0
        snap = eng.snapshot()
        assert snap["latency_p50_ms"] is not None
        assert snap["latency_p99_ms"] >= snap["latency_p50_ms"]
        assert snap["queue_depth"] == 0 and snap["inflight"] == 0
        # exactly-once: a second resolution attempt is an engine bug
        with pytest.raises(RuntimeError, match="resolved twice"):
            fut._resolve(None, 0.0)

    def test_async_dispatcher_end_to_end(self):
        rng = np.random.default_rng(7)
        eng = PolymulEngine(batch_slots=4, max_pending=8)
        pl = eng.plan(n=64, t=3, v=30)
        reqs = [self._mk(pl, rng) for _ in range(10)]
        with eng:
            assert eng.running
            futs = [
                eng.submit(pl, za, zb, timeout=5.0) for za, zb in reqs
            ]
            outs = [f.result(timeout=30.0) for f in futs]
        assert not eng.running
        for (za, zb), out in zip(reqs, outs):
            want = np.asarray(api.polymul(pl, za[None], zb[None]))[0]
            assert np.array_equal(out, want)
        assert eng.stats["served"] == 10
        assert eng.pending() == 0
