"""Family dispatch: one API over dense / moe / ssm / hybrid / encdec.

    init_params(key, cfg)                        -> params
    forward(params, cfg, batch, remat=False)     -> logits (B, S, V)
    init_cache(cfg, batch, max_len, enc_len=0)   -> decode cache
    decode_step(params, cfg, cache, batch)       -> (logits, cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid
from repro.models import layers as L
from repro.models import ssm
from repro.models import transformer as tfm


# --------------------------------------------------------------------------
# pure-SSM (mamba2) decoder-only model
# --------------------------------------------------------------------------


def _ssm_init(key, cfg: ModelConfig):
    k_e, k_m, k_h = jax.random.split(key, 3)
    layers = jax.vmap(
        lambda k: {"ln": L.rmsnorm_init(cfg.d_model), "mixer": ssm.mamba2_init(k, cfg)}
    )(jax.random.split(k_m, cfg.n_layers))
    return {
        "embed": L.dense_init(k_e, (cfg.padded_vocab, cfg.d_model), scale=0.02),
        "layers": layers,
        "final_norm": L.rmsnorm_init(cfg.d_model),
        "lm_head": L.dense_init(k_h, (cfg.d_model, cfg.padded_vocab)),
    }


def _ssm_forward(params, cfg: ModelConfig, batch, *, remat=False,
                 remat_group: int = 1, last_only: bool = False):
    x = tfm.embed_inputs(params, cfg, batch)

    def one(x, lp):
        h, _ = ssm.mamba2_apply(
            lp["mixer"], L.rmsnorm(lp["ln"], x, cfg.norm_eps), cfg
        )
        return x + h

    stack = params["layers"]
    if remat_group > 1 and cfg.n_layers % remat_group == 0:
        stack = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers // remat_group, remat_group) + a.shape[1:]),
            stack,
        )

        def body(x, lps):
            for i in range(remat_group):
                x = one(x, jax.tree.map(lambda a: a[i], lps))
            return x, None

    else:

        def body(x, lp):
            return one(x, lp), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, stack)
    if last_only:
        x = x[:, -1:]
    return tfm.unembed(params, cfg, x)


def _ssm_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    d_in, H, P, N = ssm.dims(cfg)
    conv_dim = d_in + 2 * N
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros(
            (cfg.n_layers, batch, cfg.conv_kernel - 1, conv_dim), L.CDTYPE
        ),
        "pos": jnp.zeros((), jnp.int32),
    }


def _ssm_decode(params, cfg: ModelConfig, cache, batch):
    x = tfm.embed_inputs(params, cfg, batch)

    def body(x, inp):
        lp, s, c = inp
        h, (ns, nc) = ssm.mamba2_apply(
            lp["mixer"], L.rmsnorm(lp["ln"], x, cfg.norm_eps), cfg,
            ssm_state=s, conv_state=c,
        )
        return x + h, (ns, nc)

    x, (ns, nc) = jax.lax.scan(body, x, (params["layers"], cache["ssm"], cache["conv"]))
    new_cache = {"ssm": ns, "conv": nc, "pos": cache["pos"] + x.shape[1]}
    return tfm.unembed(params, cfg, x), new_cache


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        return tfm.init_params(key, cfg)
    if cfg.family == "ssm":
        return _ssm_init(key, cfg)
    if cfg.family == "hybrid":
        return hybrid.init_params(key, cfg)
    if cfg.family == "encdec":
        return encdec.init_params(key, cfg)
    raise ValueError(cfg.family)


def forward(params, cfg: ModelConfig, batch, *, remat: bool = False,
            remat_group: int = 1, last_only: bool = False):
    if cfg.family in ("dense", "moe"):
        return tfm.forward(params, cfg, batch, remat=remat,
                           remat_group=remat_group, last_only=last_only)
    if cfg.family == "ssm":
        return _ssm_forward(params, cfg, batch, remat=remat,
                            remat_group=remat_group, last_only=last_only)
    if cfg.family == "hybrid":
        return hybrid.forward(params, cfg, batch, remat=remat, last_only=last_only)
    if cfg.family == "encdec":
        return encdec.forward(params, cfg, batch, remat=remat, last_only=last_only)
    raise ValueError(cfg.family)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    if cfg.family in ("dense", "moe"):
        return tfm.init_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return _ssm_init_cache(cfg, batch, max_len)
    if cfg.family == "hybrid":
        return hybrid.init_cache(cfg, batch, max_len)
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, max_len, enc_len or max_len)
    raise ValueError(cfg.family)


def decode_step(params, cfg: ModelConfig, cache, batch):
    if cfg.family in ("dense", "moe"):
        logits, cache = tfm.decode_step(params, cfg, cache, batch)
    elif cfg.family == "ssm":
        logits, cache = _ssm_decode(params, cfg, cache, batch)
    elif cfg.family == "hybrid":
        logits, cache = hybrid.decode_step(params, cfg, cache, batch)
    elif cfg.family == "encdec":
        logits, cache = encdec.decode_step(params, cfg, cache, batch)
    else:
        raise ValueError(cfg.family)
    # decode emits true-vocab logits (tiny slice; samplers index real ids)
    return logits[..., : cfg.vocab], cache


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
